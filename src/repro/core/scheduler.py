"""Tensorized FIFO scheduling (paper §IV-A resource managers).

OpenDC's scheduler walks an event queue and places each task with first-fit.
The tensorized equivalent exploits one invariant: FIFO priority is arrival
order, and the task table is pre-sorted by arrival, so "the next tasks to
schedule" are simply *the first K eligible rows* — selected with a cumsum
instead of a per-step argsort.  Placement itself is a bounded `fori_loop`
(first-fit needs sequential core accounting); K bounds work per step and is
exact whenever K >= eligible tasks that can start this step.

Two modes:
  first_fit  — exact greedy placement, the production path (also available as
               a Pallas kernel, kernels/first_fit.py).
  aggregate  — capacity-only admission that ignores per-host fragmentation;
               this reproduces the optimistic behaviour of analytical models
               the paper critiques (§III), and is also much cheaper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import SchedulerConfig
from .state import HostTable, TaskTable, PENDING, RUNNING


# Below this host count, per-host sums run as a one-hot matmul instead of
# segment_sum: XLA's CPU scatter path costs ~50us per call at N=1024, which
# dominated the whole scan step (the sums run EVERY step, inside the hot
# loop), while the [h, N] matmul is tens of FLOPs per task.  Above it the
# one-hot mask's h*N footprint stops paying for itself.
_MATMUL_MAX_HOSTS = 256


def _per_host_sum(vals, seg, h: int):
    """segment_sum(vals, seg, h), scatter-free for small host counts.

    Exact for integer-valued inputs (core/GPU counts) in any order; for
    float-weighted inputs the summation order differs from segment_sum by
    ULP-level rounding only.
    """
    if h <= _MATMUL_MAX_HOSTS:
        onehot = (seg[None, :] == jnp.arange(h, dtype=seg.dtype)[:, None])
        return onehot.astype(vals.dtype) @ vals
    return jax.ops.segment_sum(vals, seg, h)


def free_capacity(tasks: TaskTable, hosts: HostTable):
    """Recompute per-host free CPU cores and GPUs from the task table."""
    h = hosts.cores.shape[0]
    # host >= 0 like failures.interrupt_tasks: the clip below is only index
    # safety — without the mask a RUNNING task carrying host == -1 would be
    # silently billed to host 0
    running = (tasks.status == RUNNING) & (tasks.host >= 0)
    seg = jnp.clip(tasks.host, 0, h - 1)
    used_c = _per_host_sum(jnp.where(running, tasks.cores, 0.0), seg, h)
    used_g = _per_host_sum(jnp.where(running, tasks.gpus, 0.0), seg, h)
    avail = (hosts.active & hosts.up).astype(jnp.float32)
    return hosts.cores * avail - used_c, hosts.n_gpus * avail - used_g


def host_utilization(tasks: TaskTable, hosts: HostTable):
    """Per-host CPU/GPU utilization in [0,1] from running tasks."""
    h = hosts.cores.shape[0]
    running = (tasks.status == RUNNING) & (tasks.host >= 0)
    seg = jnp.clip(tasks.host, 0, h - 1)
    cpu = _per_host_sum(
        jnp.where(running, tasks.cores * tasks.cpu_util, 0.0), seg, h)
    gpu = _per_host_sum(
        jnp.where(running, tasks.gpus * tasks.gpu_util, 0.0), seg, h)
    cpu_u = jnp.where(hosts.cores > 0, cpu / jnp.maximum(hosts.cores, 1e-6), 0.0)
    gpu_u = jnp.where(hosts.n_gpus > 0, gpu / jnp.maximum(hosts.n_gpus, 1e-6), 0.0)
    return jnp.clip(cpu_u, 0.0, 1.0), jnp.clip(gpu_u, 0.0, 1.0)


def _eligible(tasks: TaskTable, now, shift_ok):
    arrived = tasks.arrival <= now
    return (tasks.status == PENDING) & arrived & shift_ok


def _first_k_indices(mask, k: int):
    """Indices of the first k True rows of mask (padded with -1).

    csum[i] counts True rows in [0..i], so the s-th True index is the first
    i with csum[i] == s + 1 — k binary searches on the sorted cumsum instead
    of the scatter this used to be (XLA CPU scatters serialize; inside the
    per-step hot loop that was most of the scheduler's fixed cost).
    """
    csum = jnp.cumsum(mask.astype(jnp.int32))
    wanted = jnp.arange(1, k + 1, dtype=jnp.int32)
    idx = jnp.searchsorted(csum, wanted, side="left").astype(jnp.int32)
    return jnp.where(wanted <= csum[-1], idx, -1)


def _first_k_by_priority(mask, priority, k: int, levels: int):
    """First k True rows of mask in (priority desc, arrival) order.

    ONE sorted-key pass: the level-major flattened mask `[L, T] -> [L*T]`
    (levels descending, rows in arrival order within each level) is already
    sorted by the composite key (priority level, arrival), so a single
    cumsum + searchsorted selects the first k set bits and `idx % T`
    recovers the task rows.  The per-level form this replaces
    (`_first_k_by_priority_reference`) ran `levels + 1` cumsum passes and a
    gather merge — per step, inside the hot loop, and batched over every
    grid cell; it was the single largest term in the typed-variant vmap
    collapse.  `priority` may be traced.

    Equivalence with the reference (which truncates each level to k before
    merging): a row dropped by a per-level truncation sits at position
    >= k within its OWN level, so at position >= k of the merged order too
    — never selectable among the first k.  Pinned by differential tests
    (hypothesis + lexsort model) in tests/test_core_properties.py.
    """
    prio = jnp.asarray(priority)
    t = prio.shape[0]
    lvl = jnp.arange(levels - 1, -1, -1, dtype=prio.dtype)
    m = (mask[None, :] & (prio[None, :] == lvl[:, None])).reshape(-1)
    csum = jnp.cumsum(m.astype(jnp.int32))
    wanted = jnp.arange(1, k + 1, dtype=jnp.int32)
    idx = jnp.searchsorted(csum, wanted, side="left").astype(jnp.int32)
    return jnp.where(wanted <= csum[-1], idx % t, -1)


def _first_k_by_priority_reference(mask, priority, k: int, levels: int):
    """Per-level reference form of `_first_k_by_priority` (kept as the
    differential-test oracle): one `_first_k_indices` pass per priority
    level, then one merge pass over the concatenated per-level candidate
    lists.  Higher classes fill the k slots first; FIFO (row) order is
    preserved within a class because each per-level pass already returns
    rows in arrival order.
    """
    prio = jnp.asarray(priority)
    cands = [_first_k_indices(mask & (prio == p), k)
             for p in range(levels - 1, -1, -1)]
    cat = jnp.concatenate(cands)                  # [levels*k]
    sel = _first_k_indices(cat >= 0, k)           # first k valid candidates
    return jnp.where(sel >= 0, cat[jnp.maximum(sel, 0)], -1)


def schedule_first_fit(tasks: TaskTable, hosts: HostTable, now, shift_ok,
                       cfg: SchedulerConfig, slots=None, host_order=None,
                       presorted: bool = False):
    """Exact bounded first-fit.  Returns updated task table.

    `cfg.slots_per_step` is the STATIC placement bound (it shapes the
    compiled loop).  `slots`, when given, is a TRACED per-run slot count
    <= that bound: iterations past it become no-ops, so a scenario grid can
    sweep `dyn_axis(slots_per_step=...)` inside ONE compiled program — the
    fori_loop bound used to be the swept value itself, recompiling per
    point.  `slots=None` reproduces the static path bit-for-bit.

    `host_order` (i32[H] permutation, e.g. resilience.host_rank) makes the
    "first" in first-fit mean "first in that order" — failure-reactive
    placement.  None keeps natural host order.  Either way a down or
    deactivated host never fits, even for zero-footprint tasks: `0 >= 0`
    used to admit a coreless task onto a failed host (whose free capacity
    reads as exactly 0), parking it there forever.

    `presorted=True` asserts the table rows are ALREADY in
    (priority desc, arrival) order (`state.priority_schedule_order`), so
    priority admission is the plain FIFO prefix of the row order and the
    per-step `[L*T]` level-major flatten+cumsum disappears entirely.  The
    engine permutes the table once per simulation and sets this; direct
    callers with arrival-ordered tables keep the default.
    """
    k = cfg.slots_per_step
    t = tasks.arrival.shape[0]
    h_n = hosts.cores.shape[0]
    elig = _eligible(tasks, now, shift_ok)
    multi = cfg.priority_levels > 1 and not presorted
    if multi:
        # level-major flattened mask: merged (priority desc, arrival) order
        prio = jnp.asarray(tasks.priority)
        lvl = jnp.arange(cfg.priority_levels - 1, -1, -1, dtype=prio.dtype)
        m = (elig[None, :] & (prio[None, :] == lvl[:, None])).reshape(-1)
    else:  # single class, or presorted rows: row order IS admission order
        m = elig
    # One cumsum serves BOTH directions of the candidate mapping:
    # slot -> row (cand, via k binary searches) and row -> slot (rank,
    # via a gather) — the k-th set bit of m sits at the first position
    # whose cumsum equals k+1, and a set row's rank is its cumsum - 1.
    csum = jnp.cumsum(m.astype(jnp.int32))
    wanted = jnp.arange(1, k + 1, dtype=jnp.int32)
    idx = jnp.searchsorted(csum, wanted, side="left").astype(jnp.int32)
    cand = jnp.where(wanted <= csum[-1], idx % t if multi else idx, -1)
    free_c, free_g = free_capacity(tasks, hosts)
    usable = hosts.active & hosts.up
    hidx = jnp.arange(h_n, dtype=jnp.int32)
    # per-slot resource needs, gathered ONCE before the loop (the body used
    # to re-gather from the [T] columns every iteration, a batched gather
    # per iteration under vmapped grids)
    cj = jnp.maximum(cand, 0)
    nc_all = jnp.where(cand >= 0, tasks.cores[cj], 0.0)
    ng_all = jnp.where(cand >= 0, tasks.gpus[cj], 0.0)
    # suffix minima of the per-slot needs: once NO remaining candidate fits
    # on ANY usable host, every later iteration is a placement no-op (it
    # skips the candidate and changes no capacity), so the loop may stop —
    # bit-for-bit the same outcome.  Saturated steps (full hosts behind a
    # backlog, e.g. shifting holding a green-window burst) used to burn all
    # k iterations doing nothing.
    inf32 = jnp.float32(jnp.inf)
    suf_c = jax.lax.cummin(jnp.where(cand >= 0, nc_all, inf32)[::-1])[::-1]
    suf_g = jax.lax.cummin(jnp.where(cand >= 0, ng_all, inf32)[::-1])[::-1]

    # Sequential first-fit over the candidate slots, restructured for the
    # batched (vmapped-grid) hot path:
    #   * `while_loop` instead of `fori_loop(0, k)`: candidate lists are
    #     -1-padded at the tail, and iterations past the first -1 were
    #     no-ops, so stopping there is bit-for-bit the same placement.
    #     Under vmap the loop runs until every lane's candidates are done —
    #     the mean eligible count per step (1-2) instead of the static
    #     bound k (64), which was the dominant per-step cost.
    #   * the [T]-wide status/host/first_start updates leave the loop:
    #     the body only records each slot's chosen host in a k-vector
    #     (a dynamic-update-slice, not a scatter) and the table updates
    #     happen ONCE after the loop.
    #   * per-host free-capacity updates use a select instead of a scatter:
    #     `free - take * (hidx == hj)` applies `x + (-take)` to the chosen
    #     host and `x - 0.0` (an IEEE no-op) elsewhere, matching the old
    #     `.at[hj].add(-take)` bit-for-bit.
    def cond(carry):
        i, fc, fg = carry[0], carry[1], carry[2]
        ii = jnp.minimum(i, k - 1)
        more = cand[ii] >= 0
        # conservative feasibility: continue while SOME usable host clears
        # the remaining candidates' component-wise minimum needs (the minima
        # may come from different candidates, so this can keep iterating
        # past the last possible placement — but it never stops before one)
        more = more & jnp.any((fc >= suf_c[ii]) & (fg >= suf_g[ii]) & usable)
        if slots is not None:  # masked tail, as in the fori_loop form
            more = more & (i < slots)
        return (i < k) & more

    def body(carry):
        i, free_c, free_g, sel_host = carry
        ii = jnp.minimum(i, k - 1)
        need_c, need_g = nc_all[ii], ng_all[ii]
        fits = (free_c >= need_c) & (free_g >= need_g) & usable
        if host_order is None:
            h = jnp.argmax(fits)        # first host that fits (first-fit)
        else:  # first fitting host in preference order
            h = host_order[jnp.argmax(fits[host_order])]
        placed = fits[h]
        hj = jnp.where(placed, h, 0).astype(jnp.int32)
        take_c = jnp.where(placed, need_c, 0.0)
        take_g = jnp.where(placed, need_g, 0.0)
        free_c = free_c - jnp.where(hidx == hj, take_c, 0.0)
        free_g = free_g - jnp.where(hidx == hj, take_g, 0.0)
        sel_host = sel_host.at[ii].set(
            jnp.where(placed, h.astype(jnp.int32), -1))
        return i + 1, free_c, free_g, sel_host

    _, free_c, free_g, sel_host = jax.lax.while_loop(
        cond, body,
        (jnp.int32(0), free_c, free_g, jnp.full((k,), -1, jnp.int32)))
    # Deferred table writes via the INVERSE candidate map: each row's slot
    # is its rank in the admission order (csum - 1), so a [T] gather from
    # sel_host replaces the three [T]-target scatters this used to do —
    # XLA CPU serializes batched scatters per lane, and they were ~half
    # the scheduler stage's cost under vmapped grids.  Rows map to at most
    # one slot and vice versa, so the select-form updates are bitwise the
    # scatters they replace.
    if multi:
        # clip is index safety only: an out-of-range priority has
        # m[pos_t] == False (it matched no level), so it never places —
        # exactly the original per-level behaviour
        lvl_t = (cfg.priority_levels - 1
                 - jnp.clip(prio, 0, cfg.priority_levels - 1))
        pos_t = lvl_t.astype(jnp.int32) * t + jnp.arange(t, dtype=jnp.int32)
        rank = csum[pos_t] - 1
        in_k = m[pos_t] & (rank < k)
    else:
        rank = csum - 1
        in_k = elig & (rank < k)
    host_t = sel_host[jnp.clip(rank, 0, k - 1)]
    placed_t = in_k & (host_t >= 0)
    status = jnp.where(placed_t, RUNNING, tasks.status).astype(
        tasks.status.dtype)
    host = jnp.where(placed_t, jnp.maximum(host_t, 0),
                     tasks.host).astype(tasks.host.dtype)
    first_start = jnp.where(placed_t, jnp.minimum(tasks.first_start, now),
                            tasks.first_start)
    return tasks._replace(status=status, host=host, first_start=first_start)


def schedule_aggregate(tasks: TaskTable, hosts: HostTable, now, shift_ok,
                       cfg: SchedulerConfig):
    """Capacity-only admission (fragmentation-blind, analytical-model-like).

    Admits the longest FIFO prefix of eligible tasks whose total core/GPU
    demand fits the total free capacity, then maps each admitted task onto a
    host by position in the free-capacity cumsum (approximate placement).
    """
    elig = _eligible(tasks, now, shift_ok)
    free_c, free_g = free_capacity(tasks, hosts)
    total_c, total_g = jnp.sum(free_c), jnp.sum(free_g)
    need_c = jnp.where(elig, tasks.cores, 0.0)
    need_g = jnp.where(elig, tasks.gpus, 0.0)
    admit = elig & (jnp.cumsum(need_c) <= total_c) & (jnp.cumsum(need_g) <= total_g)
    # approximate host: position of the task's core-demand midpoint in the
    # cumulative free-core distribution over hosts
    cum_c = jnp.cumsum(jnp.maximum(free_c, 0.0))
    pos = jnp.cumsum(need_c) - need_c * 0.5
    host = jnp.searchsorted(cum_c, pos).astype(jnp.int32)
    h = hosts.cores.shape[0]
    host = jnp.clip(host, 0, h - 1)
    # a down/inactive host occupies a zero-width span of the cumsum, yet a
    # zero-need task's midpoint can land exactly on it (0 >= 0); bump every
    # task to the next usable host at-or-after its mapped position, and
    # refuse admission when none exists
    usable = hosts.active & hosts.up
    next_usable = jax.lax.cummin(
        jnp.where(usable, jnp.arange(h, dtype=jnp.int32), h)[::-1])[::-1]
    bumped = next_usable[host]
    admit = admit & (bumped < h)
    host = jnp.where(bumped < h, bumped, 0).astype(jnp.int32)
    return tasks._replace(
        status=jnp.where(admit, RUNNING, tasks.status).astype(jnp.int32),
        host=jnp.where(admit, host, tasks.host).astype(jnp.int32),
        first_start=jnp.where(admit, jnp.minimum(tasks.first_start, now),
                              tasks.first_start),
    )


def schedule_step(tasks: TaskTable, hosts: HostTable, now, shift_ok,
                  cfg: SchedulerConfig, slots=None, host_order=None,
                  presorted: bool = False):
    if cfg.mode == "first_fit":
        return schedule_first_fit(tasks, hosts, now, shift_ok, cfg,
                                  slots=slots, host_order=host_order,
                                  presorted=presorted)
    if cfg.mode == "aggregate":
        if cfg.priority_levels > 1:
            raise ValueError(
                "scheduler mode 'aggregate' admits the longest FIFO prefix "
                "and cannot honor priority classes; use mode='first_fit' "
                "with priority_levels > 1")
        return schedule_aggregate(tasks, hosts, now, shift_ok, cfg)
    raise ValueError(f"unknown scheduler mode '{cfg.mode}'")
