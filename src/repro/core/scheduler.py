"""Tensorized FIFO scheduling (paper §IV-A resource managers).

OpenDC's scheduler walks an event queue and places each task with first-fit.
The tensorized equivalent exploits one invariant: FIFO priority is arrival
order, and the task table is pre-sorted by arrival, so "the next tasks to
schedule" are simply *the first K eligible rows* — selected with a cumsum
instead of a per-step argsort.  Placement itself is a bounded `fori_loop`
(first-fit needs sequential core accounting); K bounds work per step and is
exact whenever K >= eligible tasks that can start this step.

Two modes:
  first_fit  — exact greedy placement, the production path (also available as
               a Pallas kernel, kernels/first_fit.py).
  aggregate  — capacity-only admission that ignores per-host fragmentation;
               this reproduces the optimistic behaviour of analytical models
               the paper critiques (§III), and is also much cheaper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import SchedulerConfig
from .state import HostTable, TaskTable, PENDING, RUNNING


# Below this host count, per-host sums run as a one-hot matmul instead of
# segment_sum: XLA's CPU scatter path costs ~50us per call at N=1024, which
# dominated the whole scan step (the sums run EVERY step, inside the hot
# loop), while the [h, N] matmul is tens of FLOPs per task.  Above it the
# one-hot mask's h*N footprint stops paying for itself.
_MATMUL_MAX_HOSTS = 256


def _per_host_sum(vals, seg, h: int):
    """segment_sum(vals, seg, h), scatter-free for small host counts.

    Exact for integer-valued inputs (core/GPU counts) in any order; for
    float-weighted inputs the summation order differs from segment_sum by
    ULP-level rounding only.
    """
    if h <= _MATMUL_MAX_HOSTS:
        onehot = (seg[None, :] == jnp.arange(h, dtype=seg.dtype)[:, None])
        return onehot.astype(vals.dtype) @ vals
    return jax.ops.segment_sum(vals, seg, h)


def free_capacity(tasks: TaskTable, hosts: HostTable):
    """Recompute per-host free CPU cores and GPUs from the task table."""
    h = hosts.cores.shape[0]
    # host >= 0 like failures.interrupt_tasks: the clip below is only index
    # safety — without the mask a RUNNING task carrying host == -1 would be
    # silently billed to host 0
    running = (tasks.status == RUNNING) & (tasks.host >= 0)
    seg = jnp.clip(tasks.host, 0, h - 1)
    used_c = _per_host_sum(jnp.where(running, tasks.cores, 0.0), seg, h)
    used_g = _per_host_sum(jnp.where(running, tasks.gpus, 0.0), seg, h)
    avail = (hosts.active & hosts.up).astype(jnp.float32)
    return hosts.cores * avail - used_c, hosts.n_gpus * avail - used_g


def host_utilization(tasks: TaskTable, hosts: HostTable):
    """Per-host CPU/GPU utilization in [0,1] from running tasks."""
    h = hosts.cores.shape[0]
    running = (tasks.status == RUNNING) & (tasks.host >= 0)
    seg = jnp.clip(tasks.host, 0, h - 1)
    cpu = _per_host_sum(
        jnp.where(running, tasks.cores * tasks.cpu_util, 0.0), seg, h)
    gpu = _per_host_sum(
        jnp.where(running, tasks.gpus * tasks.gpu_util, 0.0), seg, h)
    cpu_u = jnp.where(hosts.cores > 0, cpu / jnp.maximum(hosts.cores, 1e-6), 0.0)
    gpu_u = jnp.where(hosts.n_gpus > 0, gpu / jnp.maximum(hosts.n_gpus, 1e-6), 0.0)
    return jnp.clip(cpu_u, 0.0, 1.0), jnp.clip(gpu_u, 0.0, 1.0)


def _eligible(tasks: TaskTable, now, shift_ok):
    arrived = tasks.arrival <= now
    return (tasks.status == PENDING) & arrived & shift_ok


def _first_k_indices(mask, k: int):
    """Indices of the first k True rows of mask (padded with -1).

    csum[i] counts True rows in [0..i], so the s-th True index is the first
    i with csum[i] == s + 1 — k binary searches on the sorted cumsum instead
    of the scatter this used to be (XLA CPU scatters serialize; inside the
    per-step hot loop that was most of the scheduler's fixed cost).
    """
    csum = jnp.cumsum(mask.astype(jnp.int32))
    wanted = jnp.arange(1, k + 1, dtype=jnp.int32)
    idx = jnp.searchsorted(csum, wanted, side="left").astype(jnp.int32)
    return jnp.where(wanted <= csum[-1], idx, -1)


def _first_k_by_priority(mask, priority, k: int, levels: int):
    """First k True rows of mask in (priority desc, arrival) order.

    Priority-aware candidate selection, still scatter-free: one
    `_first_k_indices` pass per priority level (`levels` is a small static
    int from SchedulerConfig), then one merge pass over the concatenated
    per-level candidate lists.  Higher classes fill the k slots first; FIFO
    (row) order is preserved within a class because each per-level pass
    already returns rows in arrival order.  `priority` may be traced.
    """
    prio = jnp.asarray(priority)
    cands = [_first_k_indices(mask & (prio == p), k)
             for p in range(levels - 1, -1, -1)]
    cat = jnp.concatenate(cands)                  # [levels*k]
    sel = _first_k_indices(cat >= 0, k)           # first k valid candidates
    return jnp.where(sel >= 0, cat[jnp.maximum(sel, 0)], -1)


def schedule_first_fit(tasks: TaskTable, hosts: HostTable, now, shift_ok,
                       cfg: SchedulerConfig, slots=None, host_order=None):
    """Exact bounded first-fit.  Returns updated task table.

    `cfg.slots_per_step` is the STATIC placement bound (it shapes the
    compiled loop).  `slots`, when given, is a TRACED per-run slot count
    <= that bound: iterations past it become no-ops, so a scenario grid can
    sweep `dyn_axis(slots_per_step=...)` inside ONE compiled program — the
    fori_loop bound used to be the swept value itself, recompiling per
    point.  `slots=None` reproduces the static path bit-for-bit.

    `host_order` (i32[H] permutation, e.g. resilience.host_rank) makes the
    "first" in first-fit mean "first in that order" — failure-reactive
    placement.  None keeps natural host order.  Either way a down or
    deactivated host never fits, even for zero-footprint tasks: `0 >= 0`
    used to admit a coreless task onto a failed host (whose free capacity
    reads as exactly 0), parking it there forever.
    """
    k = cfg.slots_per_step
    elig = _eligible(tasks, now, shift_ok)
    if cfg.priority_levels > 1:
        cand = _first_k_by_priority(elig, tasks.priority, k,
                                    cfg.priority_levels)
    else:  # single class: the plain FIFO prefix, bit-for-bit the old path
        cand = _first_k_indices(elig, k)
    free_c, free_g = free_capacity(tasks, hosts)
    usable = hosts.active & hosts.up

    def body(i, carry):
        free_c, free_g, status, host, first_start = carry
        ti = cand[i]
        valid = ti >= 0
        if slots is not None:  # masked tail: loop runs to the static bound
            valid = valid & (i < slots)
        tj = jnp.maximum(ti, 0)
        need_c, need_g = tasks.cores[tj], tasks.gpus[tj]
        fits = (free_c >= need_c) & (free_g >= need_g) & usable
        if host_order is None:
            h = jnp.argmax(fits)        # first host that fits (first-fit)
        else:  # first fitting host in preference order
            h = host_order[jnp.argmax(fits[host_order])]
        placed = valid & fits[h]
        hj = jnp.where(placed, h, 0).astype(jnp.int32)
        take_c = jnp.where(placed, need_c, 0.0)
        take_g = jnp.where(placed, need_g, 0.0)
        free_c = free_c.at[hj].add(-take_c)
        free_g = free_g.at[hj].add(-take_g)
        tset = jnp.where(placed, tj, tasks.arrival.shape[0])  # OOB -> dropped
        status = status.at[tset].set(RUNNING, mode="drop")
        host = host.at[tset].set(h.astype(jnp.int32), mode="drop")
        first_start = first_start.at[tset].min(now, mode="drop")
        return free_c, free_g, status, host, first_start

    free_c, free_g, status, host, first_start = jax.lax.fori_loop(
        0, k, body, (free_c, free_g, tasks.status, tasks.host, tasks.first_start))
    return tasks._replace(status=status, host=host, first_start=first_start)


def schedule_aggregate(tasks: TaskTable, hosts: HostTable, now, shift_ok,
                       cfg: SchedulerConfig):
    """Capacity-only admission (fragmentation-blind, analytical-model-like).

    Admits the longest FIFO prefix of eligible tasks whose total core/GPU
    demand fits the total free capacity, then maps each admitted task onto a
    host by position in the free-capacity cumsum (approximate placement).
    """
    elig = _eligible(tasks, now, shift_ok)
    free_c, free_g = free_capacity(tasks, hosts)
    total_c, total_g = jnp.sum(free_c), jnp.sum(free_g)
    need_c = jnp.where(elig, tasks.cores, 0.0)
    need_g = jnp.where(elig, tasks.gpus, 0.0)
    admit = elig & (jnp.cumsum(need_c) <= total_c) & (jnp.cumsum(need_g) <= total_g)
    # approximate host: position of the task's core-demand midpoint in the
    # cumulative free-core distribution over hosts
    cum_c = jnp.cumsum(jnp.maximum(free_c, 0.0))
    pos = jnp.cumsum(need_c) - need_c * 0.5
    host = jnp.searchsorted(cum_c, pos).astype(jnp.int32)
    h = hosts.cores.shape[0]
    host = jnp.clip(host, 0, h - 1)
    # a down/inactive host occupies a zero-width span of the cumsum, yet a
    # zero-need task's midpoint can land exactly on it (0 >= 0); bump every
    # task to the next usable host at-or-after its mapped position, and
    # refuse admission when none exists
    usable = hosts.active & hosts.up
    next_usable = jax.lax.cummin(
        jnp.where(usable, jnp.arange(h, dtype=jnp.int32), h)[::-1])[::-1]
    bumped = next_usable[host]
    admit = admit & (bumped < h)
    host = jnp.where(bumped < h, bumped, 0).astype(jnp.int32)
    return tasks._replace(
        status=jnp.where(admit, RUNNING, tasks.status).astype(jnp.int32),
        host=jnp.where(admit, host, tasks.host).astype(jnp.int32),
        first_start=jnp.where(admit, jnp.minimum(tasks.first_start, now),
                              tasks.first_start),
    )


def schedule_step(tasks: TaskTable, hosts: HostTable, now, shift_ok,
                  cfg: SchedulerConfig, slots=None, host_order=None):
    if cfg.mode == "first_fit":
        return schedule_first_fit(tasks, hosts, now, shift_ok, cfg,
                                  slots=slots, host_order=host_order)
    if cfg.mode == "aggregate":
        if cfg.priority_levels > 1:
            raise ValueError(
                "scheduler mode 'aggregate' admits the longest FIFO prefix "
                "and cannot honor priority classes; use mode='first_fit' "
                "with priority_levels > 1")
        return schedule_aggregate(tasks, hosts, now, shift_ok, cfg)
    raise ValueError(f"unknown scheduler mode '{cfg.mode}'")
