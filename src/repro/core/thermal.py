"""Weather-driven cooling: chiller + free-cooling economizer + cooling tower.

The engine's `stage_power` yields *IT* power; this module converts it into
*facility* power and on-site water use, per step, from the wet-bulb
temperature (weathertraces/) and the cooling setpoint:

  * A fixed fan/pump overhead (CRAH fans, chilled-water pumps) scales with IT
    load regardless of weather.
  * A water-side economizer carries the whole heat load for free when the
    wet-bulb temperature is at least `economizer_range_c` below the setpoint;
    in between, the chiller duty ramps linearly to 1 (partial free cooling).
  * The chiller is a Carnot-fraction machine: the cooling tower supplies
    condenser water at wet-bulb + approach, so the compressor lift — and with
    it COP — is a function of weather.  COP is monotone non-increasing in
    wet-bulb temperature and clipped to a realistic [1, max] band.
  * Chiller-path heat (load + compressor work) is rejected through the wet
    tower by evaporation; economized heat uses dry coils and consumes no
    water.  Litres evaporated per kWh of heat rejected folds latent heat and
    blowdown into one calibrated constant.

Everything is elementwise jnp on traced scalars, so the whole model fuses
into the simulation step and `cooling_setpoint` can be a scenario-grid axis.
`dynamic_pue` = facility/IT power; integrated over a run this yields the
PUE/WUE metrics in `core/metrics.py`.
"""
from __future__ import annotations

import jax.numpy as jnp

from .config import CoolingConfig

_T_ZERO_K = 273.15
_MIN_LIFT_C = 1.0  # floor on the compressor lift: no free chilling


def economizer_fraction(wet_bulb_c, cfg: CoolingConfig, setpoint_c=None,
                        availability=None):
    """Fraction of the heat load the chiller must carry (0 = all free).

    0 for wet-bulb <= setpoint - economizer_range_c (the cutoff), ramping
    linearly to 1 at the setpoint: the classic water-side economizer duty
    curve.  `setpoint_c` may be a traced scalar (grid axis); defaults to the
    config's static setpoint.

    `availability` (core/resilience.py chiller-derate series) scales how
    much of the free-cooling path is usable: the chiller fraction becomes
    ``1 - (1 - frac) * availability``.  None (the default) keeps the
    original expression — gating on None rather than multiplying by 1.0
    matters because ``1 - (1 - frac)`` is not bitwise `frac` in f32.
    """
    sp = jnp.float32(cfg.setpoint_c) if setpoint_c is None else setpoint_c
    wb = jnp.asarray(wet_bulb_c, jnp.float32)
    rng = jnp.maximum(jnp.float32(cfg.economizer_range_c), 1e-6)
    frac = jnp.clip((wb - (sp - rng)) / rng, 0.0, 1.0)
    if availability is None:
        return frac
    return 1.0 - (1.0 - frac) * jnp.asarray(availability, jnp.float32)


def chiller_cop(wet_bulb_c, cfg: CoolingConfig, setpoint_c=None,
                max_cop_scale=None):
    """Weather-dependent chiller COP (monotone non-increasing in wet-bulb).

    The tower delivers condenser water at wet-bulb + approach; adding the
    condenser-loop lift gives the hot-side temperature.  COP is a fixed
    fraction of the Carnot limit over that lift, clipped to [1, max_cop].

    `max_cop_scale` (chiller-derate series) shrinks the achievable-COP
    ceiling while facility equipment is degraded; None keeps the original
    clip bound bitwise.
    """
    sp = jnp.float32(cfg.setpoint_c) if setpoint_c is None else setpoint_c
    wb = jnp.asarray(wet_bulb_c, jnp.float32)
    t_cond = wb + cfg.tower_approach_c + cfg.condenser_lift_c
    lift = jnp.maximum(t_cond - sp, _MIN_LIFT_C)
    cop = cfg.carnot_efficiency * (sp + _T_ZERO_K) / lift
    if max_cop_scale is None:
        return jnp.clip(cop, 1.0, cfg.max_cop)
    ceil = jnp.maximum(cfg.max_cop * jnp.asarray(max_cop_scale, jnp.float32),
                       1.0)
    return jnp.clip(cop, 1.0, ceil)


def cooling_step(it_power_kw, wet_bulb_c, cfg: CoolingConfig, setpoint_c=None,
                 chiller_derate=None):
    """One cooling decision.  Returns (cooling_kw, water_l_per_h).

    cooling_kw   — fan/pump overhead + compressor power.
    water_l_per_h — cooling-tower evaporation (chiller-path heat only;
                    economized heat rejects through dry coils).
    All arguments may be traced scalars/arrays; fuses into the sim step.

    `chiller_derate` < 1 (facility failure injection, core/resilience.py)
    degrades both paths at once: less economizer availability (more load
    on the chiller) AND a lower achievable COP — a derated facility burns
    more energy to move the same heat.  None is the bitwise-identical
    healthy path.
    """
    frac = economizer_fraction(wet_bulb_c, cfg, setpoint_c,
                               availability=chiller_derate)
    cop = chiller_cop(wet_bulb_c, cfg, setpoint_c,
                      max_cop_scale=chiller_derate)
    fan_kw = cfg.fan_pump_overhead * it_power_kw
    chiller_kw = frac * it_power_kw / cop
    water_l_per_h = (frac * it_power_kw + chiller_kw) * cfg.evap_l_per_kwh_heat
    return fan_kw + chiller_kw, water_l_per_h


def reclaimable_heat_kw(it_power_kw, cooling_kw, wet_bulb_c,
                        cfg: CoolingConfig, setpoint_c=None,
                        chiller_derate=None):
    """Chiller-path heat flow (load + compressor work) available for reuse.

    District-heating reclaim taps the condenser loop, so only the
    chiller-path heat counts — economized heat rejects at near-ambient
    temperature through dry coils and is useless to a heat network.
    Recomputed from the already-known cooling power (works for both the
    fused-kernel and the elementwise cooling paths): chiller power is the
    cooling power minus the weather-independent fan/pump overhead, and the
    chiller-path load is `economizer_fraction * IT`.  Pass the same
    `chiller_derate` as `cooling_step` so the split stays consistent.
    """
    frac = economizer_fraction(wet_bulb_c, cfg, setpoint_c,
                               availability=chiller_derate)
    chiller_kw = cooling_kw - cfg.fan_pump_overhead * it_power_kw
    return frac * it_power_kw + chiller_kw


def dynamic_pue(it_power_kw, wet_bulb_c, cfg: CoolingConfig, setpoint_c=None):
    """Instantaneous PUE = facility/IT power (>= 1; load-independent here
    because both cooling terms scale linearly with IT power)."""
    cooling_kw, _ = cooling_step(it_power_kw, wet_bulb_c, cfg, setpoint_c)
    it = jnp.maximum(jnp.asarray(it_power_kw, jnp.float32), 1e-9)
    return (it + cooling_kw) / it
