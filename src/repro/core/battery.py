"""Battery model + threshold charge/discharge policy (paper §V-B1).

Policy: charge while the carbon intensity is below a rolling-mean threshold
(past week), discharge above it.  As an optimization the battery waits until
the carbon intensity stops decreasing before charging (charging at the trough
rather than on the way down).  Charge/discharge rate scales linearly with
capacity (3 kW/kWh by default).

The threshold and trough signals depend only on the exogenous carbon trace, so
they are precomputed outside the scan (`precompute_battery_signals`) — a
tensorization win unavailable to the event-driven design.
"""
from __future__ import annotations

import jax.numpy as jnp

from .config import BatteryConfig
from .state import BatteryState


def precompute_battery_signals(ci_trace, dt_h: float, cfg: BatteryConfig):
    """Returns (threshold[S], ci_rising[S]) for a carbon trace ci_trace[S].

    threshold[t] = mean of the trailing week's carbon intensity (expanding mean
    before a full window exists).  ci_rising[t] = trace stopped decreasing at t.
    """
    ci = jnp.asarray(ci_trace, jnp.float32)
    s = ci.shape[0]
    w = max(int(round(cfg.threshold_window_h / dt_h)), 1)
    csum = jnp.concatenate([jnp.zeros(1, jnp.float32), jnp.cumsum(ci)])
    idx = jnp.arange(s)
    lo = jnp.maximum(idx + 1 - w, 0)
    window = (idx + 1 - lo).astype(jnp.float32)
    threshold = (csum[idx + 1] - csum[lo]) / window
    prev = jnp.concatenate([ci[:1], ci[:-1]])
    ci_rising = ci >= prev
    return threshold, ci_rising


def battery_step(batt: BatteryState, dc_power_kw, ci, threshold, ci_rising,
                 dt_h: float, cfg: BatteryConfig, capacity_kwh=None,
                 rate_kw=None):
    """One battery decision.  Returns (new_state, grid_power_kw, discharged_kwh).

    Charging ADDS to the grid draw (this is the power-spike effect the paper
    quantifies in Fig 9A); discharging serves datacenter load from storage.
    `capacity_kwh` / `rate_kw` may be traced values to sweep battery sizing
    inside a single compiled program (paper Fig 7/8/12).
    """
    if not cfg.enabled:
        return batt, dc_power_kw, jnp.float32(0.0)

    cap = jnp.float32(cfg.capacity_kwh) if capacity_kwh is None else capacity_kwh
    rate_kw = (cap * cfg.charge_rate_kw_per_kwh if rate_kw is None
               else rate_kw)
    eff = jnp.float32(cfg.round_trip_efficiency)

    want_charge = ci < threshold
    if cfg.wait_for_trough:
        want_charge = want_charge & ci_rising
    want_discharge = (ci > threshold) & (batt.charge > 0.0)

    # charge: limited by C-rate and remaining headroom
    headroom_kw = (cap - batt.charge) / dt_h
    charge_kw = jnp.minimum(rate_kw, jnp.maximum(headroom_kw, 0.0))
    charge_kw = jnp.where(want_charge, charge_kw, 0.0)

    # discharge: limited by C-rate, stored energy, and actual load
    avail_kw = batt.charge / dt_h
    discharge_kw = jnp.minimum(jnp.minimum(rate_kw, avail_kw), dc_power_kw)
    discharge_kw = jnp.where(want_discharge & ~want_charge, discharge_kw, 0.0)

    new_charge = jnp.clip(batt.charge + (charge_kw * eff - discharge_kw) * dt_h,
                          0.0, cap)
    grid_kw = dc_power_kw + charge_kw - discharge_kw
    new_state = BatteryState(charge=new_charge, was_charging=want_charge)
    return new_state, grid_kw, discharge_kw * dt_h


def battery_embodied_rate_kg_per_h(cfg: BatteryConfig) -> float:
    """Embodied carbon attributed per hour of battery ownership (paper §V-C2)."""
    if not cfg.enabled:
        return 0.0
    from .config import HOURS_PER_YEAR

    total = cfg.capacity_kwh * cfg.embodied_kg_per_kwh
    return total / (cfg.lifetime_years * HOURS_PER_YEAR)
