"""Battery model + dispatch policies (paper §V-B1, extended with cost).

Three dispatch policies decide when to charge/discharge (the storage
*physics* — C-rate caps, round-trip efficiency, SoC clipping — is shared):

  * 'carbon'  — the paper's policy: charge while the carbon intensity is
    below a rolling-mean threshold (past week), discharge above it, and
    optionally wait for the trough (charge when the intensity stops
    decreasing, not on the way down).
  * 'price'   — spot-market arbitrage: charge while the price is strictly
    below the forward `price_charge_quantile`, discharge strictly above
    the `price_discharge_quantile` (bands from
    core/pricing.precompute_price_signals; a constant price trace makes
    both conditions vacuous, so arbitrage degenerates to a no-op).
  * 'blended' — a carbon-vs-cost objective: normalized margins of the two
    policies mixed by `dispatch_lambda` (1 = pure carbon, 0 = pure price).
    `dispatch_lambda` may be a TRACED scalar (dyn ctx key
    `dispatch_lambda`), so `dyn_axis(dispatch_lambda=[...])` sweeps the
    whole cost-carbon Pareto front in one compiled program; the endpoints
    select the exact single-objective decisions, so lambda=1 reproduces
    'carbon' (and lambda=0 'price') bit-for-bit.

When the renewables subsystem runs (core/renewables.py), every policy is
additionally *surplus-aware* (`surplus_aware_dispatch`): PV generation
beyond the facility load charges the battery regardless of the policy's
opinion (free energy beats any threshold), a surplus-only charge never
draws from the grid, and the battery never discharges into its own
surplus.

The threshold/trough/band signals depend only on the exogenous traces, so
they are precomputed outside the scan (`precompute_battery_signals`,
`pricing.precompute_price_signals`) — a tensorization win unavailable to
the event-driven design.
"""
from __future__ import annotations

import jax.numpy as jnp

from .config import BatteryConfig
from .state import BatteryState

POLICIES = ("carbon", "price", "blended")


def precompute_battery_signals(ci_trace, dt_h: float, cfg: BatteryConfig):
    """Returns (threshold[S], ci_rising[S]) for a carbon trace ci_trace[S].

    threshold[t] = mean of the trailing week's carbon intensity (expanding mean
    before a full window exists).  ci_rising[t] = trace stopped decreasing at t.
    """
    ci = jnp.asarray(ci_trace, jnp.float32)
    s = ci.shape[0]
    w = max(int(round(cfg.threshold_window_h / dt_h)), 1)
    csum = jnp.concatenate([jnp.zeros(1, jnp.float32), jnp.cumsum(ci)])
    idx = jnp.arange(s)
    lo = jnp.maximum(idx + 1 - w, 0)
    window = (idx + 1 - lo).astype(jnp.float32)
    threshold = (csum[idx + 1] - csum[lo]) / window
    prev = jnp.concatenate([ci[:1], ci[:-1]])
    ci_rising = ci >= prev
    return threshold, ci_rising


def dispatch_decision(cfg: BatteryConfig, charge, ci, threshold, ci_rising,
                      price=None, price_lo=None, price_hi=None,
                      dispatch_lambda=None):
    """(want_charge, want_discharge) bools under the configured policy.

    The policy string is static (it selects the compiled decision logic);
    `dispatch_lambda` is traced so grids can sweep the blend.  The blended
    endpoints are selected EXACTLY (`jnp.where` on lambda >= 1 / <= 0)
    rather than relying on the mixed score's sign, which keeps lambda=1
    bitwise identical to the 'carbon' policy (tests/test_pricing_properties).
    """
    want_charge = ci < threshold
    if cfg.wait_for_trough:
        want_charge = want_charge & ci_rising
    want_discharge = (ci > threshold) & (charge > 0.0)
    if cfg.policy == "carbon":
        return want_charge, want_discharge
    if cfg.policy not in POLICIES:
        raise ValueError(f"unknown battery dispatch policy '{cfg.policy}'; "
                         f"pick one of {POLICIES}")
    if price is None or price_lo is None or price_hi is None:
        raise ValueError(f"battery policy '{cfg.policy}' needs price "
                         "signals: enable cfg.pricing (core/pricing.py)")
    p_charge = price < price_lo
    p_discharge = (price > price_hi) & (charge > 0.0)
    if cfg.policy == "price":
        return p_charge, p_discharge
    lam = (jnp.float32(cfg.dispatch_lambda) if dispatch_lambda is None
           else dispatch_lambda)
    # normalized margins: carbon in units of its rolling-mean threshold,
    # price in units of the arbitrage band's midpoint — both dimensionless,
    # so the lambda mix is scale-free (gCO2/kWh vs $/kWh never compare raw)
    c_ref = jnp.maximum(threshold, 1e-6)
    p_ref = jnp.maximum(0.5 * (price_lo + price_hi), 1e-6)
    charge_score = (lam * (threshold - ci) / c_ref
                    + (1.0 - lam) * (price_lo - price) / p_ref)
    discharge_score = (lam * (ci - threshold) / c_ref
                       + (1.0 - lam) * (price - price_hi) / p_ref)
    b_charge = charge_score > 0.0
    if cfg.wait_for_trough:
        b_charge = b_charge & ci_rising
    b_discharge = (discharge_score > 0.0) & (charge > 0.0)
    pure_c = lam >= 1.0
    pure_p = lam <= 0.0
    blended_charge = jnp.where(pure_c, want_charge,
                               jnp.where(pure_p, p_charge, b_charge))
    blended_discharge = jnp.where(pure_c, want_discharge,
                                  jnp.where(pure_p, p_discharge, b_discharge))
    return blended_charge, blended_discharge


def surplus_aware_dispatch(want_charge, want_discharge, surplus_kw):
    """Extend a policy dispatch decision with PV-surplus awareness.

    The 'surplus' extension of `dispatch_decision` (core/renewables.py
    supplies `surplus_kw`, the PV generation beyond the facility load):

      * free energy beats any policy — the battery absorbs surplus even
        when the carbon/price policy declines to charge, but a
        surplus-only charge may never draw from the grid (the returned
        `charge_cap_kw` is the surplus itself unless the policy asked
        for a charge, in which case grid top-up stays allowed);
      * the battery never discharges into its own surplus (the energy
        would round-trip straight back out as export at efficiency < 1).

    Returns (want_charge, want_discharge, charge_cap_kw).
    """
    has_surplus = surplus_kw > 0.0
    charge_cap_kw = jnp.where(want_charge, jnp.float32(jnp.inf), surplus_kw)
    return (want_charge | has_surplus,
            want_discharge & ~has_surplus,
            charge_cap_kw)


def battery_flow_step(batt: BatteryState, load_kw, ci, threshold, ci_rising,
                      dt_h: float, cfg: BatteryConfig, capacity_kwh=None,
                      rate_kw=None, price=None, price_lo=None, price_hi=None,
                      dispatch_lambda=None, pv_surplus_kw=None):
    """One battery decision in ledger terms.  Returns
    (new_state, batt_charge_kw, batt_discharge_kw).

    `load_kw` is the load the battery may serve — the full facility draw,
    or the PV-netted residual when the renewables subsystem runs
    (core/renewables.net_load_split).  `pv_surplus_kw`, when given, enables
    the surplus-aware dispatch extension (`surplus_aware_dispatch`); None
    reproduces the supply-free decision exactly.  The caller settles the
    grid side of the ledger from the returned charge/discharge split.
    """
    if not cfg.enabled:
        zero = jnp.float32(0.0)
        return batt, zero, zero

    cap = jnp.float32(cfg.capacity_kwh) if capacity_kwh is None else capacity_kwh
    rate_kw = (cap * cfg.charge_rate_kw_per_kwh if rate_kw is None
               else rate_kw)
    eff = jnp.float32(cfg.round_trip_efficiency)

    want_charge, want_discharge = dispatch_decision(
        cfg, batt.charge, ci, threshold, ci_rising, price=price,
        price_lo=price_lo, price_hi=price_hi,
        dispatch_lambda=dispatch_lambda)
    charge_cap_kw = None
    if pv_surplus_kw is not None:
        want_charge, want_discharge, charge_cap_kw = surplus_aware_dispatch(
            want_charge, want_discharge, pv_surplus_kw)

    # charge: limited by C-rate and remaining headroom (and, for a
    # surplus-only charge, by the surplus itself — no grid draw)
    headroom_kw = (cap - batt.charge) / dt_h
    charge_kw = jnp.minimum(rate_kw, jnp.maximum(headroom_kw, 0.0))
    if charge_cap_kw is not None:
        charge_kw = jnp.minimum(charge_kw, charge_cap_kw)
    charge_kw = jnp.where(want_charge, charge_kw, 0.0)

    # discharge: limited by C-rate, stored energy, and actual load
    avail_kw = batt.charge / dt_h
    discharge_kw = jnp.minimum(jnp.minimum(rate_kw, avail_kw), load_kw)
    discharge_kw = jnp.where(want_discharge & ~want_charge, discharge_kw, 0.0)

    new_charge = jnp.clip(batt.charge + (charge_kw * eff - discharge_kw) * dt_h,
                          0.0, cap)
    new_state = BatteryState(charge=new_charge, was_charging=want_charge)
    return new_state, charge_kw, discharge_kw


def battery_step(batt: BatteryState, dc_power_kw, ci, threshold, ci_rising,
                 dt_h: float, cfg: BatteryConfig, capacity_kwh=None,
                 rate_kw=None, price=None, price_lo=None, price_hi=None,
                 dispatch_lambda=None):
    """One battery decision.  Returns (new_state, grid_power_kw, discharged_kwh).

    Charging ADDS to the grid draw (this is the power-spike effect the paper
    quantifies in Fig 9A); discharging serves datacenter load from storage.
    `capacity_kwh` / `rate_kw` may be traced values to sweep battery sizing
    inside a single compiled program (paper Fig 7/8/12); `price`/`price_lo`/
    `price_hi`/`dispatch_lambda` feed the price-aware dispatch policies.
    Thin wrapper over `battery_flow_step` (the ledger-term core).
    """
    if not cfg.enabled:
        return batt, dc_power_kw, jnp.float32(0.0)
    new_state, charge_kw, discharge_kw = battery_flow_step(
        batt, dc_power_kw, ci, threshold, ci_rising, dt_h, cfg,
        capacity_kwh=capacity_kwh, rate_kw=rate_kw, price=price,
        price_lo=price_lo, price_hi=price_hi,
        dispatch_lambda=dispatch_lambda)
    grid_kw = dc_power_kw + charge_kw - discharge_kw
    return new_state, grid_kw, discharge_kw * dt_h


def battery_embodied_rate_kg_per_h(cfg: BatteryConfig) -> float:
    """Embodied carbon attributed per hour of battery ownership (paper §V-C2)."""
    if not cfg.enabled:
        return 0.0
    from .config import HOURS_PER_YEAR

    total = cfg.capacity_kwh * cfg.embodied_kg_per_kwh
    return total / (cfg.lifetime_years * HOURS_PER_YEAR)
