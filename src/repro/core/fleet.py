"""Multi-datacenter fleet engine: R heterogeneous regions as ONE program.

`core/spatial.py` places tasks across regional datacenters; this module runs
the placed fleet: each region has its own carbon trace, weather trace,
battery sizing, cooling setpoint and host count, and the whole fleet is one
jitted `jax.vmap` of the UNCHANGED engine (`core/engine.simulate`) — the
paper's composability claim (C1) at facility granularity.  Per-region
heterogeneity rides on the existing dyn mechanism: host counts through
`n_active_hosts` (horizontal-scaling mask), battery sizing through
`batt_capacity_kwh`/`batt_rate_kw`, climate through per-region wet-bulb
traces, so spatial shifting composes with every other technique, and
`core/grid.py`'s `region_axis`/`fleet_axis` make per-region parameters
sweepable grid dimensions on top.

The contract (differential-tested): a fleet of R=1 regions reproduces
`simulate` on the same workload bit-for-bit, and a fleet grid equals the
per-scenario Python loop of `simulate_fleet` calls.

Placement is host-side and exogenous (traces + task list only); the fleet
program itself is pure jnp, so grids vmap it freely.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import SimConfig
from .engine import simulate
from . import telemetry as telemetry_mod
from .metrics import SimResult, fleet_totals, summarize
from .spatial import (spatial_assign, spatial_assign_online, split_by_region)
from .state import HostTable, TaskTable

# dyn keys that may be per-region vectors (length R) in a fleet
PER_REGION_KEYS = ("n_active_hosts", "batt_capacity_kwh", "batt_rate_kw",
                   "cooling_setpoint", "dispatch_lambda", "pv_capacity_kw",
                   "seed")

POLICIES = ("greedy", "spill", "round_robin")


class FleetResult(NamedTuple):
    """`total` aggregates the fleet (metrics.fleet_totals); `per_region` is a
    SimResult whose fields carry a leading (in grids: trailing) R axis."""
    total: SimResult
    per_region: SimResult


class FleetSpec:
    """R regional datacenters: per-region traces, sizing, and a placement
    policy.  Everything per-region is an optional length-R array; scalars
    broadcast.  Arrays live host-side (numpy) — a FleetSpec is scenario
    *structure*, not traced data — and the same spec can be re-run under
    different `dyn` overrides or swept through `core/grid.py`.

    ci_traces:      f32[R, S]  per-region carbon intensity (required)
    wb_traces:      f32[R, S]  per-region wet-bulb weather (needs cooling)
    price_traces:   f32[R, S]  per-region electricity prices (needs pricing)
    pv_traces:      f32[R, S]  per-region solar capacity factors (needs
                               renewables, core/renewables.py)
    n_active_hosts: i32[R]     per-region host count (default: all hosts)
    batt_capacity_kwh, batt_rate_kw, cooling_setpoint, pv_capacity_kw,
    seeds:          f32/i32[R]
    capacity_frac:  float      aggregate core-hour cap per region, as a
                               multiple of its fair (host-count-weighted)
                               share of total work; None = uncapped
    policy:         'greedy' (capped aggregate, core/spatial.py),
                    'spill' (online time-resolved re-routing), or
                    'round_robin' (carbon-blind baseline)
    forecast_h:     placement forecast horizon (hours)
    """

    def __init__(self, ci_traces, wb_traces=None, price_traces=None,
                 pv_traces=None, n_active_hosts=None,
                 batt_capacity_kwh=None, batt_rate_kw=None,
                 cooling_setpoint=None, pv_capacity_kw=None, seeds=None,
                 capacity_frac: float | None = None, policy: str = "greedy",
                 forecast_h: float = 24.0):
        self.ci_traces = np.asarray(ci_traces, np.float32)
        assert self.ci_traces.ndim == 2, (
            f"ci_traces must be f32[R, S], got {self.ci_traces.shape}")
        r = self.ci_traces.shape[0]
        if policy not in POLICIES:
            raise ValueError(f"unknown fleet policy '{policy}'; "
                             f"pick one of {POLICIES}")
        self.wb_traces = None
        if wb_traces is not None:
            self.wb_traces = np.asarray(wb_traces, np.float32)
            assert self.wb_traces.shape[0] == r, (
                f"wb_traces regions {self.wb_traces.shape[0]} != {r}")
        self.price_traces = None
        if price_traces is not None:
            self.price_traces = np.asarray(price_traces, np.float32)
            assert self.price_traces.shape[0] == r, (
                f"price_traces regions {self.price_traces.shape[0]} != {r}")
        self.pv_traces = None
        if pv_traces is not None:
            self.pv_traces = np.asarray(pv_traces, np.float32)
            assert self.pv_traces.shape[0] == r, (
                f"pv_traces regions {self.pv_traces.shape[0]} != {r}")

        def per_region(x, dtype):
            if x is None:
                return None
            a = np.broadcast_to(np.asarray(x, dtype), (r,)).copy()
            return a

        self.n_active_hosts = per_region(n_active_hosts, np.int32)
        self.batt_capacity_kwh = per_region(batt_capacity_kwh, np.float32)
        self.batt_rate_kw = per_region(batt_rate_kw, np.float32)
        self.cooling_setpoint = per_region(cooling_setpoint, np.float32)
        self.pv_capacity_kw = per_region(pv_capacity_kw, np.float32)
        self.seeds = per_region(seeds, np.int32)
        self.capacity_frac = capacity_frac
        self.policy = policy
        self.forecast_h = float(forecast_h)

    @property
    def n_regions(self) -> int:
        return self.ci_traces.shape[0]

    def replace(self, **kw) -> "FleetSpec":
        args = dict(ci_traces=self.ci_traces, wb_traces=self.wb_traces,
                    price_traces=self.price_traces, pv_traces=self.pv_traces,
                    n_active_hosts=self.n_active_hosts,
                    batt_capacity_kwh=self.batt_capacity_kwh,
                    batt_rate_kw=self.batt_rate_kw,
                    cooling_setpoint=self.cooling_setpoint,
                    pv_capacity_kw=self.pv_capacity_kw, seeds=self.seeds,
                    capacity_frac=self.capacity_frac, policy=self.policy,
                    forecast_h=self.forecast_h)
        args.update(kw)
        return FleetSpec(**args)

    def per_region_dyn(self) -> dict:
        """The spec's per-region dyn values as length-R arrays (the leaves
        the fleet vmap maps over)."""
        dyn = {}
        for key, val in (("n_active_hosts", self.n_active_hosts),
                         ("batt_capacity_kwh", self.batt_capacity_kwh),
                         ("batt_rate_kw", self.batt_rate_kw),
                         ("cooling_setpoint", self.cooling_setpoint),
                         ("pv_capacity_kw", self.pv_capacity_kw),
                         ("seed", self.seeds)):
            if val is not None:
                dyn[key] = jnp.asarray(val)
        return dyn

    def region_cores(self, hosts: HostTable) -> np.ndarray:
        """f64[R] concurrent-core capacity per region (first-n active)."""
        cores = np.asarray(hosts.cores, np.float64)
        csum = np.concatenate([[0.0], np.cumsum(cores)])
        if self.n_active_hosts is None:
            return np.full(self.n_regions, csum[-1])
        n = np.clip(self.n_active_hosts, 0, cores.shape[0])
        return csum[n]

    def capacity_core_h(self, tasks: TaskTable, hosts: HostTable):
        """f64[R] aggregate core-hour caps from `capacity_frac`, split in
        proportion to each region's core capacity; None when uncapped."""
        if self.capacity_frac is None:
            return None
        arrival = np.asarray(tasks.arrival)
        valid = np.isfinite(arrival)
        total = float(np.sum((np.asarray(tasks.cores, np.float64)
                              * np.asarray(tasks.duration, np.float64))[valid]))
        share = self.region_cores(hosts)
        share = share / max(share.sum(), 1e-9)
        return self.capacity_frac * total * share


def fleet_place(tasks: TaskTable, hosts: HostTable, fleet: FleetSpec,
                dt_h: float, n_steps: int | None = None) -> np.ndarray:
    """Run the fleet's placement policy.  Returns i32[T] region ids."""
    if fleet.policy == "round_robin":
        arrival = np.asarray(tasks.arrival)
        valid = np.isfinite(arrival)
        region = np.full(arrival.shape[0], -1, np.int32)
        region[valid] = (np.arange(int(valid.sum()))
                        % fleet.n_regions).astype(np.int32)
        return region
    if fleet.policy == "spill":
        return spatial_assign_online(tasks, fleet.ci_traces, dt_h,
                                     fleet.region_cores(hosts),
                                     n_steps=n_steps,
                                     forecast_h=fleet.forecast_h)
    return spatial_assign(tasks, fleet.ci_traces, dt_h,
                          capacity_core_h=fleet.capacity_core_h(tasks, hosts),
                          forecast_h=fleet.forecast_h)


def fleet_cell(tasks_r: TaskTable, hosts: HostTable, cfg: SimConfig,
               ci_traces, wb_traces=None, scalar_dyn: dict | None = None,
               per_region_dyn: dict | None = None,
               price_traces=None, pv_traces=None) -> FleetResult:
    """The jit/vmap-safe fleet program over PRE-PLACED stacked tables.

    tasks_r: TaskTable with leading region axis [R, W] (split_by_region).
    scalar_dyn: traced values shared by every region; per_region_dyn: dict
    of length-R arrays, one value per region.  wb_traces/price_traces/
    pv_traces are optional [R, S] per-region weather/tariff/solar families.
    This is the cell the grid engine vmaps — `simulate_fleet` is its
    host-side front door.
    """
    scalar_dyn = dict(scalar_dyn or {})
    per_region_dyn = dict(per_region_dyn or {})
    ci = jnp.asarray(ci_traces, jnp.float32)
    wb = (None if wb_traces is None
          else jnp.asarray(wb_traces, jnp.float32))
    pr = (None if price_traces is None
          else jnp.asarray(price_traces, jnp.float32))
    pv = (None if pv_traces is None
          else jnp.asarray(pv_traces, jnp.float32))

    def one(tt, tr, per_r, wb_r, pr_r, pv_r):
        dyn = {**scalar_dyn, **per_r}
        if pr_r is not None:
            dyn["price_trace"] = pr_r
        if pv_r is not None:
            dyn["pv_cf_trace"] = pv_r
        final, _ = simulate(tt, hosts, tr, cfg, dyn=dyn, weather_trace=wb_r)
        return summarize(final, cfg)

    in_axes = (0, 0, 0, None if wb is None else 0, None if pr is None else 0,
               None if pv is None else 0)
    per = jax.vmap(one, in_axes=in_axes)(tasks_r, ci, per_region_dyn, wb, pr,
                                         pv)
    return FleetResult(total=fleet_totals(per), per_region=per)


def _fleet_cell_spill(tasks_r: TaskTable, hosts: HostTable, cfg: SimConfig,
                      ci_traces, wb_traces=None, scalar_dyn: dict | None = None,
                      per_region_dyn: dict | None = None,
                      price_traces=None, pv_traces=None) -> FleetResult:
    """`fleet_cell` with the regions COUPLED step-by-step: after every
    simulation step, up to `cfg.resilience.max_spills_per_step` interrupted
    tasks move from failing regions to the healthiest one
    (core/resilience.cross_region_spill) — fleet-level failure-reactive
    placement.

    Structure: `fleet_cell` vmaps the whole `simulate` (scan inside vmap);
    here the nesting flips to scan-of-vmapped-step so the spill hook can
    run between steps with all regions' tables in hand.  vmap-of-scan and
    scan-of-vmap compute the same per-region step math, and the spill is a
    value-preserving no-op while every region is healthy, so with no
    failures this reproduces `fleet_cell` (pinned in
    tests/test_resilience.py).  Stage-pipeline backend only; the per-step
    ctx mirrors `engine.build_step_fn`.
    """
    from . import resilience as resilience_mod
    from . import scaling as scaling_mod
    from .engine import (_advance_clock, build_step_inputs, default_pipeline,
                         init_energy_flow)
    from .state import init_sim_state

    scalar_dyn = dict(scalar_dyn or {})
    per_region_dyn = dict(per_region_dyn or {})
    ci = jnp.asarray(ci_traces, jnp.float32)
    wb = (None if wb_traces is None
          else jnp.asarray(wb_traces, jnp.float32))
    pr = (None if price_traces is None
          else jnp.asarray(price_traces, jnp.float32))
    pv = (None if pv_traces is None
          else jnp.asarray(pv_traces, jnp.float32))

    def prep(tt, tr, per_r, wb_r, pr_r, pv_r):
        """Per-region init: mirrors the front half of engine.simulate."""
        dyn = {**scalar_dyn, **per_r}
        if wb_r is not None:
            dyn["wet_bulb_trace"] = wb_r
        if pr_r is not None:
            dyn["price_trace"] = pr_r
        if pv_r is not None:
            dyn["pv_cf_trace"] = pv_r
        h = hosts
        if "n_active_hosts" in dyn:
            h = scaling_mod.with_scale(h, dyn["n_active_hosts"])
        inputs = build_step_inputs(tr, cfg, dyn=dyn)
        for k in ("wet_bulb_trace", "price_trace", "pv_cf_trace",
                  "pdu_cap_kw"):
            dyn.pop(k, None)
        state0 = init_sim_state(tt, h, dyn.get("seed", cfg.seed))
        state0 = state0._replace(throttle=jnp.float32(1.0))
        return state0, inputs, dyn

    in_axes = (0, 0, 0, None if wb is None else 0, None if pr is None else 0,
               None if pv is None else 0)
    states0, inputs, dyn_r = jax.vmap(prep, in_axes=in_axes)(
        tasks_r, ci, per_region_dyn, wb, pr, pv)

    stages = default_pipeline(cfg)

    def one_step(state, inp, dyn):
        ctx = {"ci": inp.ci, "batt_threshold": inp.batt_threshold,
               "ci_rising": inp.ci_rising,
               "shift_threshold": inp.shift_threshold,
               "wet_bulb_c": inp.wet_bulb_c, "price": inp.price,
               "price_lo": inp.price_lo, "price_hi": inp.price_hi,
               "pv_cf": inp.pv_cf,
               "chiller_derate": inp.chiller_derate,
               "pdu_cap_kw": inp.pdu_cap_kw,
               "flow": init_energy_flow(), **dyn}
        for stage in stages:
            state, ctx = stage(state, ctx)
        return _advance_clock(state, cfg)

    vstep = jax.vmap(one_step)
    xs = jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), inputs)  # [S, R]
    max_spills = int(cfg.resilience.max_spills_per_step)

    def scan_body(states, inp_t):
        states = vstep(states, inp_t, dyn_r)
        tasks, metrics = resilience_mod.cross_region_spill(
            states.tasks, states.hosts, states.metrics, max_spills)
        return states._replace(tasks=tasks, metrics=metrics), None

    with telemetry_mod.stage_scope("fleet.spill_scan"):
        finals, _ = jax.lax.scan(scan_body, states0, xs, length=cfg.n_steps)
    per = jax.vmap(lambda st: summarize(st, cfg))(finals)
    return FleetResult(total=fleet_totals(per), per_region=per)


def simulate_fleet(tasks: TaskTable, hosts: HostTable, cfg: SimConfig,
                   fleet: FleetSpec, dyn: dict | None = None,
                   region=None, width: int | None = None,
                   jit: bool = True) -> FleetResult:
    """Run R regional datacenters as one compiled vmapped program.

    tasks: ONE fresh task table (as from `make_task_table`) — placement
    happens here, at submission time, via `fleet.policy` (pass `region` to
    override with a precomputed i32[T] assignment).  hosts: the per-region
    host inventory (identical chassis across regions; heterogeneous *counts*
    via `fleet.n_active_hosts`).  `dyn` adds traced values on top of the
    spec: scalars apply to every region, length-R arrays per region.

    Returns a FleetResult: `total` (fleet-aggregated SimResult) and
    `per_region` (leading axis R).  With R=1 this reproduces
    `simulate`+`summarize` bit-for-bit (tests/test_fleet.py).
    """
    if fleet.wb_traces is not None and not cfg.cooling.enabled:
        # same contract as the grid path (ScenarioGrid._check_cfg): refuse
        # to silently drop the per-region weather
        raise ValueError("the fleet carries wb_traces but "
                         "cfg.cooling.enabled is False: the per-region "
                         "weather would be ignored")
    if fleet.price_traces is not None and not cfg.pricing.enabled:
        raise ValueError("the fleet carries price_traces but "
                         "cfg.pricing.enabled is False: the per-region "
                         "prices would be ignored")
    if fleet.pv_traces is not None and not cfg.renewables.enabled:
        raise ValueError("the fleet carries pv_traces but "
                         "cfg.renewables.enabled is False: the per-region "
                         "PV resource would be ignored")
    spill = cfg.resilience.enabled and cfg.resilience.spill_interrupted
    if cfg.resilience.spill_interrupted and not cfg.resilience.enabled:
        raise ValueError("cfg.resilience.spill_interrupted requires "
                         "cfg.resilience.enabled (the spill hook reacts to "
                         "failure signals the resilience loops produce)")
    if spill:
        # the coupled executor replays engine.build_step_fn's ctx assembly
        # per step; features that change the scan signature are out of scope
        if cfg.backend != "stage-pipeline":
            raise ValueError("spill_interrupted supports only the "
                             f"'stage-pipeline' backend, got {cfg.backend!r}")
        if cfg.probes.enabled or cfg.collect_series:
            raise ValueError("spill_interrupted does not compose with "
                             "probes or collect_series")
        for k in ("arrival_trace", "interactive_frac"):
            if k in (dyn or {}):
                raise ValueError(f"spill_interrupted does not support the "
                                 f"'{k}' dyn key")
        if width is None:
            # full-width tables so every region has INVALID slots to
            # receive spilled tasks regardless of the initial placement
            width = tasks.n
    if region is None:
        with telemetry_mod.span("fleet.place", policy=fleet.policy):
            region = fleet_place(tasks, hosts, fleet, cfg.dt_h,
                                 n_steps=cfg.n_steps)
    stacked = split_by_region(tasks, region, fleet.n_regions, width=width)
    per_region_dyn = fleet.per_region_dyn()
    scalar_dyn = {}
    for key, val in (dyn or {}).items():
        arr = jnp.asarray(val)
        if key in PER_REGION_KEYS and arr.ndim >= 1:
            assert arr.shape[0] == fleet.n_regions, (
                f"per-region dyn '{key}' has length {arr.shape[0]}, "
                f"fleet has {fleet.n_regions} regions")
            per_region_dyn[key] = arr
        else:
            scalar_dyn[key] = val

    if spill:
        fn = _jitted_fleet_cell_spill if jit else _fleet_cell_spill
    else:
        fn = _jitted_fleet_cell if jit else fleet_cell

    def run():
        return fn(stacked, hosts, cfg, jnp.asarray(fleet.ci_traces),
                  None if fleet.wb_traces is None
                  else jnp.asarray(fleet.wb_traces),
                  scalar_dyn, per_region_dyn,
                  None if fleet.price_traces is None
                  else jnp.asarray(fleet.price_traces),
                  None if fleet.pv_traces is None
                  else jnp.asarray(fleet.pv_traces))

    if telemetry_mod.enabled() and not telemetry_mod.is_tracing(
            (stacked, scalar_dyn, per_region_dyn)):
        with telemetry_mod.run_recorder(
                "fleet", cfg, n_regions=int(fleet.n_regions),
                policy=str(fleet.policy)):
            out = run()
            jax.block_until_ready(out)
        return out
    return run()


# one shared jit cache across simulate_fleet calls: same (shapes, cfg, dyn
# keys) -> same compiled fleet program, so e.g. comparing placement policies
# re-runs one executable instead of recompiling per policy
_jitted_fleet_cell = jax.jit(fleet_cell, static_argnames=("cfg",))
_jitted_fleet_cell_spill = jax.jit(_fleet_cell_spill,
                                   static_argnames=("cfg",))
