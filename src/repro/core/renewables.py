"""On-site renewable generation: PV supply for the energy-flow ledger.

The paper's battery and temporal-shifting techniques exist to align demand
with low-carbon supply; this module adds the supply side itself.  Per step,
a PV plant of `pv_capacity_kw` nameplate capacity produces

    pv_kw = pv_capacity_kw * cf(t)

from a capacity-factor trace cf(t) in [0, 1] (renewabletraces/synthetic.py,
dyn key `pv_cf_trace` / grid axis `renewable_axis`).  Generation enters the
engine's `EnergyFlow` ledger (core/engine.py) where it is netted against
the facility load:

  * load first — PV serves IT + cooling power directly;
  * battery second — surplus preferentially charges the battery
    (core/battery.surplus_aware_dispatch: free energy beats any dispatch
    policy, and the battery never discharges into its own surplus);
  * grid last — the remainder is exported when
    `cfg.renewables.export_allowed` (earning the pricing subsystem's export
    tariff) or curtailed when the site may not back-feed.

`pv_capacity_kw` may be a traced dyn value (`dyn_axis(pv_capacity_kw=...)`)
so PV-sizing studies sweep inside one compiled program, and fleets carry
per-region capacity factors (`FleetSpec(pv_traces=...)`).
"""
from __future__ import annotations

import jax.numpy as jnp

from .config import RenewableConfig


def pv_power_kw(capacity_kw, capacity_factor):
    """Instantaneous PV output.  Both arguments may be traced scalars."""
    return jnp.maximum(capacity_kw * capacity_factor, 0.0)


def net_load_split(load_kw, pv_kw):
    """(net_load_kw, surplus_kw): generation netted against facility load.

    Exactly one of the two is nonzero — PV either falls short of the load
    (net import remains) or overshoots it (surplus to store/export/curtail).
    """
    net_load = jnp.maximum(load_kw - pv_kw, 0.0)
    surplus = jnp.maximum(pv_kw - load_kw, 0.0)
    return net_load, surplus


def split_surplus(surplus_kw, charge_kw, cfg: RenewableConfig):
    """Route a PV surplus.  Returns (pv_to_batt_kw, grid_export_kw,
    curtailed_kw).

    The battery's charge decision (which may exceed the surplus: grid
    top-up when the dispatch policy asks for it) absorbs surplus first;
    the remainder is exported when the site may back-feed, else curtailed.
    `export_allowed` is static config: it selects the compiled routing.
    """
    pv_to_batt = jnp.minimum(charge_kw, surplus_kw)
    remainder = surplus_kw - pv_to_batt
    zero = jnp.zeros_like(remainder)
    if cfg.export_allowed:
        return pv_to_batt, remainder, zero
    return pv_to_batt, zero, remainder
