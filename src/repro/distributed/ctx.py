"""Mesh context threading for intermediate sharding constraints.

Model code annotates activations with logical PartitionSpecs via `constrain`.
When a mesh is installed (launch/dry-run path) the constraint becomes a real
`with_sharding_constraint`; on single-device CPU tests it is a no-op, so the
same model code runs everywhere.  Axis names absent from the installed mesh
(e.g. "pod" on the single-pod mesh) are dropped from the spec.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def _filter_spec(spec: P, axis_names) -> P:
    """Drop mesh axes the installed mesh does not have."""
    out = []
    for part in spec:
        if part is None:
            out.append(None)
        elif isinstance(part, (tuple, list)):
            kept = tuple(a for a in part if a in axis_names)
            out.append(kept if kept else None)
        else:
            out.append(part if part in axis_names else None)
    return P(*out)


def filter_spec(spec: P) -> P:
    mesh = current_mesh()
    if mesh is None:
        return spec
    return _filter_spec(spec, set(mesh.axis_names))


def constrain(x, spec: P):
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return x
    fspec = _filter_spec(spec, set(mesh.axis_names))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fspec))


def sharding_for(spec: P) -> NamedSharding | None:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, _filter_spec(spec, set(mesh.axis_names)))
