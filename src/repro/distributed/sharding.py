"""Sharding utilities: PartitionSpec trees -> NamedShardings, parameter
placement, and elastic re-meshing.

Specs in model code are written against the *logical* axis set
("pod", "data", "model"); `shardings_for` drops axes the concrete mesh does
not have, so the same spec tree serves the single-pod (16,16) mesh, the
multi-pod (2,16,16) mesh, and tiny CPU test meshes.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .ctx import _filter_spec


def shardings_for(mesh: Mesh, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree on `mesh`."""
    names = set(mesh.axis_names)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _filter_spec(s, names)),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def _divisible_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec axes that do not evenly divide the array dimension.

    pjit rejects input shardings whose axis size does not divide the dim
    (e.g. batch=1 decode cells over the ("pod","data") axes, or odd vocab
    sizes over `model`); replicating that dimension is always legal."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, part in enumerate(spec):
        if part is None or i >= len(shape):
            out.append(None if i >= len(shape) else part)
            continue
        axes = part if isinstance(part, (tuple, list)) else (part,)
        div = 1
        for a in axes:
            div *= sizes.get(a, 1)
        out.append(part if div and shape[i] % div == 0 else None)
    return P(*out)


def shardings_for_shaped(mesh: Mesh, abstract_tree, spec_tree):
    """Like shardings_for, but validates divisibility against the abstract
    (ShapeDtypeStruct) tree and replicates any non-dividing dimension."""
    names = set(mesh.axis_names)
    flat_a, treedef = jax.tree.flatten(abstract_tree)
    flat_s = treedef.flatten_up_to(spec_tree)
    out = [NamedSharding(mesh, _divisible_spec(_filter_spec(s, names),
                                               a.shape, mesh))
           for a, s in zip(flat_a, flat_s)]
    return treedef.unflatten(out)


def place(mesh: Mesh, tree, spec_tree):
    """device_put a concrete pytree according to a spec tree."""
    sh = shardings_for(mesh, spec_tree)
    return jax.tree.map(jax.device_put, tree, sh)


def remesh(tree, old_mesh: Mesh, new_mesh: Mesh, spec_tree):
    """Elastic re-meshing: move a sharded pytree onto a different mesh
    (different device count / topology).  Used on restart after losing or
    gaining nodes; combined with checkpoint.restore this is the recovery
    path for node failures."""
    del old_mesh  # resharding goes host-side; source mesh is implicit
    host = jax.tree.map(jax.device_get, tree)
    return place(new_mesh, host, spec_tree)


def bytes_per_device(tree, mesh: Mesh, spec_tree) -> int:
    """Static estimate of per-device bytes for a spec'd pytree (upper bound:
    ceil-divides uneven shards)."""
    names = dict(zip(mesh.axis_names, mesh.devices.shape))

    def leaf_bytes(leaf, spec):
        shape = list(leaf.shape)
        fspec = _filter_spec(spec, set(mesh.axis_names))
        for i, part in enumerate(fspec):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            div = 1
            for a in axes:
                div *= names[a]
            shape[i] = -(-shape[i] // div)
        n = 1
        for s in shape:
            n *= s
        return n * jax.numpy.dtype(leaf.dtype).itemsize

    flat_l, treedef = jax.tree.flatten(tree)
    flat_s = treedef.flatten_up_to(spec_tree)
    return sum(leaf_bytes(l, s) for l, s in zip(flat_l, flat_s))
