"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 200 \
        --reduced --batch 8 --seq 128 [--carbon-aware] [--failures 0.02]

Runs a real training loop (reduced configs train a ~100M-class model on CPU;
full configs are for the TPU target) through the framework's production path:
sharded params on whatever mesh is available, stateless data pipeline,
AdamW, periodic checkpointing with restart-on-failure, and optionally the
paper's temporal-shifting technique via the carbon-aware trainer.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.carbontraces.synthetic import make_region_traces
from repro.configs import get_config, reduced as reduced_cfg
from repro.core.config import ShiftingConfig
from repro.data.pipeline import DataConfig, TokenPipeline, entropy_floor
from repro.models.config import ShapeCell
from repro.models.registry import get_model
from repro.train import checkpoint as ckpt_lib
from repro.train.carbon_aware import CarbonAwareConfig, run_carbon_aware_training
from repro.train.optimizer import AdamWConfig
from repro.train.step import (TrainConfig, init_train_state, make_train_step)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/steamx_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--carbon-aware", action="store_true",
                    help="temporal-shift training around carbon peaks")
    ap.add_argument("--failures", type=float, default=0.0,
                    help="per-step failure probability (tests restart path)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = reduced_cfg(args.arch) if args.reduced else get_config(args.arch)
    model = get_model(cfg)
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps),
        grad_compression=args.grad_compression)

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    pipe = TokenPipeline(dcfg)
    state = init_train_state(model, jax.random.PRNGKey(args.seed), tcfg)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"entropy_floor={entropy_floor(dcfg):.3f}")

    start_step = 0
    if args.resume:
        last = ckpt_lib.latest_step(args.ckpt_dir)
        if last is not None:
            state = ckpt_lib.restore(args.ckpt_dir, last, state)
            start_step = last
            print(f"resumed from step {last}")

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}

    if args.carbon_aware:
        traces = make_region_traces(n_steps=24 * 60, dt_h=1.0, n_regions=1,
                                    seed=args.seed)
        ca = CarbonAwareConfig(
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            shifting=ShiftingConfig(enabled=True),
            failure_prob_per_step=args.failures, seed=args.seed)
        state, rep = run_carbon_aware_training(
            model, tcfg, state, batch_fn, args.steps, traces[0], ca)
        print(json.dumps({
            "steps": rep.steps_done, "sim_hours": round(rep.sim_hours, 2),
            "paused_hours": round(rep.paused_hours, 2),
            "pauses": rep.n_pauses, "failures": rep.n_failures,
            "restores": rep.n_restores,
            "op_carbon_kg": round(rep.op_carbon_kg, 3),
            "baseline_carbon_kg": round(rep.baseline_carbon_kg, 3),
            "carbon_reduction_pct": round(rep.carbon_reduction_pct, 2),
            "final_loss": rep.losses[-1] if rep.losses else None}))
        return

    train_step = jax.jit(make_train_step(model, tcfg))
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    step = start_step
    while step < args.steps:
        if args.failures and rng.random() < args.failures:
            last = ckpt_lib.latest_step(args.ckpt_dir)
            if last is not None:
                print(f"[failure injected @ step {step}] restoring {last}")
                state = ckpt_lib.restore(args.ckpt_dir, last, state)
                step = last
                continue
        state, metrics = train_step(state, batch_fn(step))
        step += 1
        if step % args.log_every == 0 or step == args.steps:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/max(step-start_step,1):.2f}s/step)")
        if step % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt_dir, step, state)
            ckpt_lib.prune(args.ckpt_dir, keep=2)
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
