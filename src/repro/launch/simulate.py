"""STEAM simulation driver — run sustainability-technique sweeps from the CLI.

    PYTHONPATH=src python -m repro.launch.simulate --workload surf \
        --techniques B,TS --regions 16 --days 14 [--scale 0.1]

This is the paper's experiment runner: pick a workload (synthetic
Surf/Marconi/Borg-calibrated generators), a set of techniques, and a number
of carbon regions; one vmapped/jitted tensor program evaluates all regions at
once and reports carbon/energy/SLA metrics (paper Figs 5-12 are built from
sweeps like these — see benchmarks/).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.carbontraces.synthetic import make_region_traces
from repro.core import (BatteryConfig, FailureConfig, ShiftingConfig,
                        SimConfig, carbon_reduction_pct, sweep_regions,
                        with_scale)
from repro.workloads.synthetic import SPECS, make_workload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=list(SPECS), default="surf")
    ap.add_argument("--techniques", default="",
                    help="comma list of B,TS (HS via --active-hosts)")
    ap.add_argument("--active-hosts", type=int, default=None,
                    help="horizontal scaling: power off all but N hosts")
    ap.add_argument("--scale", type=float, default=0.05,
                    help="shrink the datacenter+workload for CPU runs")
    ap.add_argument("--regions", type=int, default=8)
    ap.add_argument("--days", type=float, default=14.0)
    ap.add_argument("--dt", type=float, default=0.25)
    ap.add_argument("--battery-kwh", type=float, default=None,
                    help="default: 1.1 kWh/host (the paper's Surf optimum "
                         "315 kWh / 277 hosts, scale-invariant)")
    ap.add_argument("--failures", action="store_true")
    ap.add_argument("--tasks-cap", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    tasks, hosts, spec, meta = make_workload(
        args.workload, scale=args.scale, seed=args.seed,
        n_tasks_cap=args.tasks_cap, dt_h=args.dt, horizon_days=args.days)
    if args.active_hosts is not None:
        hosts = with_scale(hosts, args.active_hosts)

    techs = set(filter(None, args.techniques.upper().split(",")))
    n_steps = int(args.days * 24 / args.dt)
    batt_kwh = (args.battery_kwh if args.battery_kwh is not None
                else 1.1 * meta["n_hosts"])
    cfg = SimConfig(
        dt_h=args.dt, n_steps=n_steps,
        battery=BatteryConfig(enabled="B" in techs,
                              capacity_kwh=batt_kwh),
        shifting=ShiftingConfig(enabled="TS" in techs),
        failures=FailureConfig(enabled=args.failures),
        embodied=meta["embodied"],
    )
    traces = make_region_traces(n_steps, args.dt, args.regions, args.seed)

    res = sweep_regions(tasks, hosts, traces, cfg)
    base_cfg = cfg.replace(battery=BatteryConfig(enabled=False),
                           shifting=ShiftingConfig(enabled=False))
    base = sweep_regions(tasks, hosts, traces, base_cfg)
    red = np.asarray(carbon_reduction_pct(base, res))

    print(json.dumps({
        "workload": args.workload, "techniques": args.techniques or "none",
        "regions": args.regions, "days": args.days,
        "n_tasks": int(meta["n_tasks"]), "n_hosts": int(meta["n_hosts"]),
        "mean_total_carbon_kg": round(float(np.mean(np.asarray(res.total_carbon_kg))), 2),
        "mean_reduction_pct": round(float(np.mean(red)), 3),
        "regions_with_negative_reduction": int(np.sum(red < 0)),
        "mean_sla_violation_pct": round(
            100 * float(np.mean(np.asarray(res.sla_violation_frac))), 3),
        "mean_task_delay_h": round(float(np.mean(np.asarray(res.mean_delay_h))), 3),
        "peak_power_kw": round(float(np.max(np.asarray(res.peak_power_kw))), 2),
    }, indent=1))


if __name__ == "__main__":
    main()
