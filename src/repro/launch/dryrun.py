import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The 512 host devices exist ONLY in this process (dry-run); tests and
# benches see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell against the production meshes and extract the roofline terms.

For each cell this:
  1. builds the full-size ArchConfig and the abstract train/prefill/serve
     step inputs (ShapeDtypeStructs — nothing is allocated),
  2. jit-lowers with in/out shardings from the model's PartitionSpec trees,
  3. compiles (XLA:CPU stands in for the TPU compiler; GSPMD partitioning,
     collective insertion, and memory analysis are backend-independent),
  4. records memory_analysis / cost_analysis / per-collective byte counts
     into results/dryrun/<cell>.json for §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --list
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cell_applicable, get_config
from repro.distributed import ctx
from repro.distributed.sharding import shardings_for_shaped
from repro.launch import hlo_analysis
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models.config import ShapeCell
from repro.models.registry import get_model
from repro.train.optimizer import AdamWConfig
from repro.train.step import (TrainConfig, abstract_train_state,
                              make_train_step, train_state_specs)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(\S+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective in the per-device HLO."""
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_s, kind = m.group(1), m.group(2)
        nbytes = 0.0
        # result may be a tuple shape "(f32[8,128], f32[8,128])"
        for dt, dims in re.findall(r"(\w+)\[([\d,]*)\]", shape_s):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + nbytes
        count[kind] = count.get(kind, 0) + 1
    out["_counts"] = count
    return out


def build_cell_fn(arch_id: str, shape_name: str, mesh,
                  grad_compression: bool = False, overrides=None,
                  microbatches: int = 1):
    """Returns (fn, example_args, in_shardings) for the cell's step."""
    cfg = get_config(arch_id)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    model = get_model(cfg)
    tcfg = TrainConfig(opt=AdamWConfig(), grad_compression=grad_compression,
                       microbatches=microbatches)

    if shape.kind == "train":
        state = abstract_train_state(model, tcfg)
        sspecs = train_state_specs(model, tcfg)
        batch, bspecs = model.batch_specs(shape)
        fn = make_train_step(model, tcfg)
        args = (state, batch)
        shardings = (shardings_for_shaped(mesh, state, sspecs),
                     shardings_for_shaped(mesh, batch, bspecs))
        out_shard = (shardings[0], None)
    elif shape.kind == "prefill":
        params = model.abstract_params()
        pspecs = model.param_specs()
        batch, bspecs = model.batch_specs(shape)
        fn = model.prefill
        args = (params, batch)
        shardings = (shardings_for_shaped(mesh, params, pspecs),
                     shardings_for_shaped(mesh, batch, bspecs))
        out_shard = None
    else:  # decode
        params = model.abstract_params()
        pspecs = model.param_specs()
        (cache, tokens, pos), (cspec, tspec, posspec) = model.decode_specs(shape)
        fn = model.decode_step
        args = (params, cache, tokens, pos)
        cache_sh = shardings_for_shaped(mesh, cache, cspec)
        shardings = (shardings_for_shaped(mesh, params, pspecs), cache_sh,
                     shardings_for_shaped(mesh, tokens, tspec),
                     shardings_for_shaped(mesh, pos, posspec))
        out_shard = (None, cache_sh)
    return fn, args, shardings, out_shard, cfg, shape


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             grad_compression: bool = False, overrides=None,
             tag: str = "", microbatches: int = 1) -> dict:
    cfg0 = get_config(arch_id)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg0, shape)
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "tag": tag, "status": "skip", "reason": why}
    if not ok:
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with ctx.use_mesh(mesh):
        fn, args, in_shard, out_shard, cfg, shape = build_cell_fn(
            arch_id, shape_name, mesh, grad_compression, overrides,
            microbatches)
        jfn = jax.jit(fn, in_shardings=in_shard, out_shardings=out_shard)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    # loop-trip-scaled whole-program analysis (XLA's HloCostAnalysis counts
    # scan bodies once; see launch/hlo_analysis.py)
    full = hlo_analysis.analyze(hlo_text)
    coll = dict(full["collectives"])
    coll["_counts"] = parse_collective_bytes(hlo_text).get("_counts", {})

    chips = mesh.size
    flops_dev = float(full["flops"])
    bytes_dev = float(full["bytes"])
    coll_dev = float(full["collective_bytes"])

    # roofline terms (seconds; cost_analysis is per-device on SPMD modules,
    # so term = per-device work / per-chip rate == global/(chips*rate))
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]

    # MODEL_FLOPS: 6*N*D for train, 2*N*D for forward-only shapes
    n_active = cfg.n_active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens

    rec.update({
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "per_device": {
            "flops": flops_dev, "bytes": bytes_dev,
            "collective_bytes": coll_dev,
            "xla_cost_flops_unscaled": float(cost.get("flops", 0.0)),
            "xla_cost_bytes_unscaled": float(cost.get("bytes accessed", 0.0)),
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes
                           - mem.alias_size_in_bytes),
        },
        "collectives": coll,
        "roofline": {
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops": float(model_flops),
            "hlo_flops_global": flops_dev * chips,
            "useful_ratio": float(model_flops / max(flops_dev * chips, 1.0)),
        },
    })
    return rec


def cell_path(rec_or_key, out_dir=RESULTS_DIR):
    if isinstance(rec_or_key, dict):
        key = (rec_or_key["arch"], rec_or_key["shape"], rec_or_key["mesh"],
               rec_or_key.get("tag", ""))
    else:
        key = rec_or_key
    arch, shape, mesh, tag = key
    name = f"{arch}__{shape}__{mesh}" + (f"__{tag}" if tag else "")
    return os.path.join(out_dir, name + ".json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    if args.list:
        for a in ARCHS:
            for s in SHAPES:
                ok, why = cell_applicable(get_config(a), SHAPES[s])
                print(f"{a:24s} {s:12s} {'OK' if ok else 'SKIP: ' + why}")
        return

    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "multi" if mp else "single", args.tag)
                path = cell_path(key, args.out)
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[run] {key} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp,
                                   grad_compression=args.grad_compression,
                                   tag=args.tag)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "tag": args.tag, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"dominant={r['dominant']} "
                          f"t=(C {r['t_compute_s']:.3e}, M {r['t_memory_s']:.3e}, "
                          f"X {r['t_collective_s']:.3e}) "
                          f"useful={r['useful_ratio']:.2f} "
                          f"peakMB={rec['per_device']['peak_bytes']/2**20:.0f}",
                          flush=True)
                elif rec["status"] == "skip":
                    print(f"  skip: {rec['reason']}")
                else:
                    print(f"  ERROR: {rec['error']}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
