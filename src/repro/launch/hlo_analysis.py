"""Whole-program cost analysis of compiled (SPMD-partitioned) HLO text.

XLA's HloCostAnalysis counts a while-loop body ONCE, but our models run
layers (and attention q-blocks / SSD chunks) under `lax.scan`, so the
built-in numbers undercount by the trip count.  This analyzer re-derives the
three roofline numerators from the HLO text with loop-trip scaling:

  flops            — matmul FLOPs: every `dot` = 2 * |output| * |contracted|
  bytes            — fusion-boundary traffic: per instruction, result bytes +
                     operand bytes (control/shape ops skipped), the same
                     convention as HloCostAnalysis at fusion granularity
  collective_bytes — result bytes of all-reduce / all-gather / reduce-scatter
                     / all-to-all / collective-permute, by kind

Scaling: total(comp) = direct(comp) + Σ_while trip(body) * total(body)
                      + Σ_call 1 * total(callee)
Trip counts come from the loop-condition computation (jax scans compare the
induction variable against a constant).  All numbers are PER DEVICE: the
compiled module under SPMD is the per-device program.

This is a structural estimate (elementwise FLOPs are ignored; CPU fusion
shapes differ from TPU), which is the appropriate fidelity for a dry-run
roofline — the terms are dominated by dots, HBM-sized tensors, and
collectives, all of which are exact here.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SKIP_BYTES_OPS = {"tuple", "get-tuple-element", "parameter", "constant",
                   "bitcast", "after-all", "opt-barrier"}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")


def _split_instr(line: str):
    """'  ROOT %n = TYPE opcode(args...), attr=...' -> (n, TYPE, opcode, rest).

    TYPE may be a tuple containing nested parens/braces and /*index=N*/
    comments, so it is extracted with a bracket walk, not a regex.
    """
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].lstrip("%")
    rest = s[eq + 3:]
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str, rem = rest[: end + 1], rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rem = rest[:sp], rest[sp + 1:].lstrip()
    par = rem.find("(")
    if par <= 0:
        return None
    return name, type_str, rem[:par], rem[par + 1:]


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[list[int]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append([int(d) for d in dims.split(",") if d])
    return out


@dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


@dataclass
class _Comp:
    name: str
    direct: Totals = field(default_factory=Totals)
    whiles: list = field(default_factory=list)   # (cond_name, body_name)
    calls: list = field(default_factory=list)    # called computation names
    max_const: int = 1                           # trip-count heuristic source


def _parse(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    symbols: dict[str, str] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and line.endswith("{"):
            m = _COMP_START.match(line)
            if m:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
                symbols = {}
            continue
        if line.startswith("}"):
            continue
        if cur is None:
            continue
        m = _split_instr(line)
        if not m:
            # constants may still matter for trip counts
            c = re.search(r"constant\((\d+)\)", line)
            if c:
                cur.max_const = max(cur.max_const, int(c.group(1)))
            continue
        name, type_str, opcode, rest = m
        symbols[name] = type_str
        c = re.search(r"constant\((\d+)\)", line)
        if c:
            cur.max_const = max(cur.max_const, int(c.group(1)))

        if opcode == "while":
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            if mc and mb:
                cur.whiles.append((mc.group(1), mb.group(1)))
            continue
        for attr in ("calls", "to_apply"):
            mc = re.search(attr + r"=%?([\w\.\-]+)", line)
            if mc:
                cur.calls.append(mc.group(1))
        # branch computations of conditionals
        mbr = re.search(r"branch_computations=\{([^}]*)\}", line)
        if mbr:
            cur.calls += [b.strip().lstrip("%")
                          for b in mbr.group(1).split(",") if b.strip()]

        if opcode in _SKIP_BYTES_OPS:
            continue

        out_bytes = _shape_bytes(type_str)
        operand_bytes = 0
        for ref in re.findall(r"%([\w\.\-]+)", rest):
            if ref in symbols:
                operand_bytes += _shape_bytes(symbols[ref])
        cur.direct.bytes += out_bytes + operand_bytes

        if opcode == "dot":
            mcon = re.search(r"lhs_contracting_dims=\{([\d,\s]*)\}", line)
            refs = re.findall(r"%([\w\.\-]+)", rest)
            if mcon and refs:
                lhs_shape = _shape_dims(symbols.get(refs[0], ""))
                out_shape = _shape_dims(type_str)
                if lhs_shape and out_shape:
                    contract = 1
                    for d in mcon.group(1).split(","):
                        d = d.strip()
                        if d and int(d) < len(lhs_shape[0]):
                            contract *= lhs_shape[0][int(d)]
                    outn = 1
                    for d in out_shape[0]:
                        outn *= d
                    cur.direct.flops += 2.0 * outn * contract
        elif opcode == "convolution":
            # rare here; approximate with output * 2 * kernel-bytes/4
            cur.direct.flops += 2.0 * _shape_bytes(type_str)

        for kind in _COLLECTIVES:
            if opcode == kind or opcode == kind + "-start":
                cur.direct.coll[kind] = cur.direct.coll.get(kind, 0.0) \
                    + out_bytes
                break
    return comps


def analyze(text: str, entry: str | None = None) -> dict:
    comps = _parse(text)
    memo: dict[str, Totals] = {}
    visiting: set[str] = set()

    def total(name: str) -> Totals:
        if name in memo:
            return memo[name]
        if name not in comps or name in visiting:
            return Totals()
        visiting.add(name)
        c = comps[name]
        t = Totals()
        t.add(c.direct)
        for callee in c.calls:
            # fusion/call bodies: count their flops and collectives, but NOT
            # their internal bytes — the fusion's HBM traffic is its boundary
            # operands+result, already counted at the call site.
            sub = total(callee)
            t.flops += sub.flops
            for k, v in sub.coll.items():
                t.coll[k] = t.coll.get(k, 0.0) + v
        for cond, body in c.whiles:
            trip = comps[cond].max_const if cond in comps else 1
            t.add(total(body), mult=max(trip, 1))
            t.add(total(cond), mult=max(trip, 1))
        visiting.discard(name)
        memo[name] = t
        return t

    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
        entry = m.group(1) if m else next(iter(comps))
    t = total(entry)
    return {"flops": t.flops, "bytes": t.bytes,
            "collective_bytes": sum(t.coll.values()), "collectives": t.coll}
