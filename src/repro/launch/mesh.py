"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before the first
jax call, and tests must keep seeing 1 device.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
`pod` axis is pure data parallelism whose gradient all-reduce crosses DCN.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 1) -> Mesh:
    """Small mesh for CPU integration tests (requires the host-platform
    device-count flag to already be set by the test entrypoint)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e-class hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
